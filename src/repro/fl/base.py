"""Shared FL trainer substrate.

All algorithms (RWSADMM + the five baselines + Walkman) operate on the same
device-resident stacked client data and share jitted building blocks:
stochastic gradients, local-SGD inner loops (lax.scan), and personalized
evaluation. Batches are sampled *inside* jit with fixed shapes, so a whole
simulation reuses one compiled round function per algorithm.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.loader import FederatedData
from ..models.small import SmallModel, accuracy, cross_entropy

PyTree = Any

# ---------------------------------------------------------------------------
# round_metrics schema: one canonical contract for every engine.
# ---------------------------------------------------------------------------

#: keys every round_metrics entry must carry, whichever engine emitted it
REQUIRED_ROUND_KEYS = ("round", "comm_bytes")

#: canonical host-side types for the known metric keys (unknown keys are
#: allowed — trainers extend the schema — but a known key emitted with a
#: surprising type is a bug: it breaks the telemetry JSONL stream and
#: the eager ≡ scan equality pins). bool is NOT an int here.
ROUND_METRIC_TYPES: dict[str, type] = {
    "round": int, "comm_bytes": int, "client": int, "zone": int,
    "n_i": int, "walker": int, "staleness_max": int,
    "train_loss": float, "kappa": float, "latency_s": float,
    "energy_j": float, "staleness_p50": float, "clients": tuple,
}


def normalize_round_metrics(metrics: dict, rnd: int) -> dict:
    """Copy + backfill the keys the schema requires of every entry —
    the single normalization path both simulation engines run each
    entry through (eager per round, scan per chunk entry)."""
    m = dict(metrics)
    m.setdefault("round", rnd)
    m.setdefault("comm_bytes", 0)
    return m


def validate_round_metrics(entries: list[dict], *,
                           start_round: int = 0) -> frozenset:
    """Assert a round_metrics list obeys the canonical schema and
    return its key set: required keys present, ONE key set shared by
    every entry, known keys carrying their canonical host types, and
    ``round`` values consecutive from ``start_round``. Both engines
    must produce lists that pass this with identical key sets (the
    schema-parity test asserts exactly that)."""
    if not entries:
        return frozenset()
    keys = frozenset(entries[0])
    for i, m in enumerate(entries):
        missing = [k for k in REQUIRED_ROUND_KEYS if k not in m]
        assert not missing, f"entry {i} missing required keys {missing}"
        assert frozenset(m) == keys, (
            f"entry {i} key set {sorted(m)} != entry 0 {sorted(keys)}")
        assert m["round"] == start_round + i, (
            f"entry {i}: round={m['round']}, expected {start_round + i}")
        for k, v in m.items():
            want = ROUND_METRIC_TYPES.get(k)
            if want is None:
                continue
            ok = isinstance(v, want) and not (
                want is not bool and isinstance(v, bool))
            assert ok, (f"entry {i} key {k!r}: expected {want.__name__}, "
                        f"got {type(v).__name__} ({v!r})")
    return keys


class DeviceData(NamedTuple):
    """Stacked federated data on device (leading axis = client)."""

    x_train: jnp.ndarray  # (n, m_tr, *feat)
    y_train: jnp.ndarray  # (n, m_tr)
    n_train: jnp.ndarray  # (n,) valid counts
    x_test: jnp.ndarray   # (n, m_te, *feat)
    y_test: jnp.ndarray   # (n, m_te)
    mask_test: jnp.ndarray  # (n, m_te)

    @property
    def n_clients(self) -> int:
        return self.x_train.shape[0]


def to_device_data(fed: FederatedData) -> DeviceData:
    return DeviceData(
        x_train=jnp.asarray(fed.x_train),
        y_train=jnp.asarray(fed.y_train),
        n_train=jnp.asarray(fed.mask_train.sum(axis=1).astype(np.int32)),
        x_test=jnp.asarray(fed.x_test),
        y_test=jnp.asarray(fed.y_test),
        mask_test=jnp.asarray(fed.mask_test),
    )


def sample_batch(data: DeviceData, client: jnp.ndarray, key: jnp.ndarray,
                 batch_size: int):
    """Uniform-with-replacement minibatch ξ from one client (fixed shape)."""
    idx = jax.random.randint(key, (batch_size,), 0, data.n_train[client])
    return data.x_train[client, idx], data.y_train[client, idx]


class TrainerBase:
    """Common plumbing: loss/grad/local-SGD/eval builders for a model."""

    name: str = "base"
    personalized: bool = True
    #: whether this trainer can run on the lazy client plane
    #: (``data=ClientDataFactory`` + the bounded LRU store). Trainers
    #: that keep dense per-client ``(n, …)`` stacks in their state
    #: (Ditto, APFL, Walkman) set this False and refuse loudly.
    lazy_capable: bool = True

    def __init__(self, model: SmallModel, data,
                 batch_size: int = 20, telemetry=None, *,
                 store_capacity: int = 4096, prefetch: bool = False,
                 mesh=None):
        self.model = model
        # ``data`` is either eagerly stacked DeviceData (the dense
        # client plane) or a per-client ClientDataFactory (the lazy
        # plane, ``client_plane="lazy"``): no (n, …) arrays ever
        # materialize, clients are fetched on visit through the bounded
        # LRU ClientStore built below.
        lazy = not isinstance(data, DeviceData)
        self.client_plane = "lazy" if lazy else "dense"
        self.data_factory = data if lazy else None
        self.data = None if lazy else data
        self.batch_size = int(batch_size)
        self.n_clients = data.n_clients
        self.scenario = None   # attach_scenario() / trainer kwarg
        self.telemetry = telemetry   # TelemetryRun or None (off)
        # Static-analysis capture (repro.analysis.jaxpr_audit): when
        # armed, the drivers register every jitted step closure + the
        # exact traced call they are about to make. Off by default and
        # a single flag test per round — the hot paths are untouched.
        self._audit_capture = False
        self._audit_entries: list = []
        # Device-sharded client plane: with a mesh, every leading
        # client/capacity axis goes data-parallel over its "data" axis
        # (fl/sharding.py); without one, placement is untouched.
        self.fl_sharding = None
        if mesh is not None:
            from .sharding import FLSharding

            self.fl_sharding = (mesh if isinstance(mesh, FLSharding)
                                else FLSharding(mesh))
        self.store = None
        if lazy:
            if not self.lazy_capable:
                raise NotImplementedError(
                    f"{type(self).__name__} keeps dense per-client "
                    "(n, …) state stacks and does not support "
                    "client_plane='lazy'; pass stacked DeviceData")
            from .client_store import ClientStore

            self.store = ClientStore(self.data_factory,
                                     int(store_capacity),
                                     prefetch=prefetch,
                                     sharding=self.fl_sharding)
            self.store.telemetry = telemetry
        elif self.fl_sharding is not None:
            # Shard the dense stacked data once; the closures below
            # capture the sharded copy so jitted rounds see data-parallel
            # inputs and propagate the placement.
            data = self.fl_sharding.shard_rows(data)
            self.data = data

        def loss_fn(params, xb, yb, rng):
            logits = model.apply(params, xb, train=True, rng=rng)
            return cross_entropy(logits, yb)

        self.loss_fn = loss_fn
        self.grad_fn = jax.grad(loss_fn)
        self.value_and_grad_fn = jax.value_and_grad(loss_fn)

        def eval_row(params, x, y, m):
            logits = model.apply(params, x, train=False)
            return accuracy(logits, y, m), cross_entropy(logits, y, m)

        self._eval_row = eval_row
        # Row-based evaluation over explicit test arrays — the lazy
        # plane's eval path (the packed store rows ARE the data; there
        # is no (n, …) stack to close over).
        self.eval_rows_stacked = jax.jit(
            jax.vmap(eval_row, in_axes=(0, 0, 0, 0)))
        self.eval_rows_shared = jax.jit(
            jax.vmap(eval_row, in_axes=(None, 0, 0, 0)))

        if lazy:
            return   # dense eval/train closures below capture self.data

        def eval_client(params, client):
            logits = model.apply(params, data.x_test[client], train=False)
            m = data.mask_test[client]
            return (accuracy(logits, data.y_test[client], m),
                    cross_entropy(logits, data.y_test[client], m))

        self._eval_client = eval_client

        def train_loss_client(params, client, key):
            xb, yb = sample_batch(data, client, key, self.batch_size)
            return loss_fn(params, xb, yb, None)

        self._train_loss_client = train_loss_client

        # Personalized evaluation over all clients: params stacked (n, ...).
        self.eval_stacked = jax.jit(
            jax.vmap(eval_client, in_axes=(0, 0))
        )
        # One shared model evaluated on every client's test set.
        self.eval_shared = jax.jit(
            jax.vmap(eval_client, in_axes=(None, 0))
        )

    # -- local inner loops ------------------------------------------------
    def make_local_sgd(self, lr: float, steps: int) -> Callable:
        """(params, client, key[, data]) -> params after ``steps`` SGD
        steps on the client's data. jit/vmap-safe. ``data`` defaults to
        the dense stacked plane; the lazy plane passes the packed store
        block as a traced argument (``client`` is then a store slot)."""

        def run(params, client, key, data=None):
            data_ = self.data if data is None else data

            def body(p, k):
                xb, yb = sample_batch(data_, client, k, self.batch_size)
                g = self.grad_fn(p, xb, yb, k)
                p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
                return p, None

            keys = jax.random.split(key, steps)
            params, _ = jax.lax.scan(body, params, keys)
            return params

        return run

    # -- evaluation hooks (override personalized_params in subclasses) ----
    def personalized_params(self, state) -> PyTree | None:
        """Stacked (n, ...) personalized parameters, or None."""
        return None

    def global_params(self, state) -> PyTree | None:
        return None

    def evaluate(self, state) -> dict:
        if self.client_plane == "lazy":
            # The dense path below iterates every client's stacked test
            # set — exactly the O(n) materialization the lazy plane
            # removes. Store-backed trainers evaluate over the resident
            # (materialized) clients instead.
            return self._evaluate_lazy(state)
        out: dict[str, float] = {}
        pers = self.personalized_params(state)
        if pers is not None:
            acc, loss = self.eval_stacked(pers, jnp.arange(self.n_clients))
            out["acc_personalized"] = float(jnp.mean(acc))
            out["acc_personalized_std"] = float(jnp.std(acc))
            out["loss_personalized"] = float(jnp.mean(loss))
        glob = self.global_params(state)
        if glob is not None:
            acc, loss = self.eval_shared(glob, jnp.arange(self.n_clients))
            out["acc_global"] = float(jnp.mean(acc))
            out["loss_global"] = float(jnp.mean(loss))
        out["acc"] = out.get("acc_personalized", out.get("acc_global", 0.0))
        return out

    def _evaluate_lazy(self, state) -> dict:
        """Evaluation restricted to the MATERIALIZED clients — the lazy
        plane's answer to the dense path's all-n iteration. Runs the
        row-based eval over all capacity slots (fixed shapes, one
        executable) and averages over the occupied ones. Personalized
        rows come from :meth:`_lazy_personalized_rows` (None → global
        eval only, e.g. FedAvg); the global model from
        :meth:`global_params`. Reports how many clients the estimate
        covers (``eval_clients``) — at large n this is a resident-set
        sample of the population metric, by design."""
        store = self.store
        occ = store.gid_of >= 0                          # (capacity,)
        d = store.data

        def masked_stats(acc, loss):
            return np.asarray(acc)[occ], np.asarray(loss)[occ]

        out: dict[str, float] = {}
        pers = self._lazy_personalized_rows(state)
        if pers is not None:
            acc, loss = self.eval_rows_stacked(pers, d.x_test, d.y_test,
                                               d.mask_test)
            acc, loss = masked_stats(acc, loss)
            out["acc_personalized"] = float(acc.mean()) if len(acc) else 0.0
            out["acc_personalized_std"] = (float(acc.std())
                                           if len(acc) else 0.0)
            out["loss_personalized"] = (float(loss.mean())
                                        if len(loss) else 0.0)
        glob = self.global_params(state)
        if glob is not None:
            acc, loss = self.eval_rows_shared(glob, d.x_test, d.y_test,
                                              d.mask_test)
            acc, loss = masked_stats(acc, loss)
            out["acc_global"] = float(acc.mean()) if len(acc) else 0.0
            out["loss_global"] = float(loss.mean()) if len(loss) else 0.0
        out["acc"] = out.get("acc_personalized",
                             out.get("acc_global", 0.0))
        out["eval_clients"] = int(occ.sum())
        return out

    def _lazy_personalized_rows(self, state) -> PyTree | None:
        """Per-slot ``(capacity, …)`` personalized parameters for the
        lazy eval path, or None when this trainer evaluates the global
        model only. RWSADMM substitutes visited clients' x rows; the
        adaptation-based baselines adapt the global model on each
        resident slot's data rows."""
        return None

    # -- lazy client-plane plumbing (client_plane="lazy") -----------------
    def _state_clients(self, state) -> PyTree:
        """Where the packed per-client state pytree lives in this
        trainer's state. The FedAvg-family baselines keep NO per-client
        state — the store then manages only the packed data block."""
        return ()

    def _state_visited(self, state):
        return None

    def _with_clients(self, state, clients):
        return state

    def _store_template(self) -> PyTree:
        """Single-client init row the store broadcasts into fresh slots
        (empty for trainers with no per-client state)."""
        return ()

    def _reset_store(self) -> PyTree:
        """(Re)initialize the client store for a fresh run; returns the
        packed ``(capacity, …)`` state pytree. Call from init_state."""
        return self.store.reset(self._store_template())

    def _ensure_round(self, state, idx):
        """Make one working set resident and translate global ids →
        store slots. ``idx`` is the raw padded id array — padding id 0
        rides along deliberately, so the dense plane's masked ±0.0
        scatter-adds land on the same client's row in both planes."""
        clients, stats = self.store.ensure(self._state_clients(state),
                                           np.asarray(idx).reshape(-1))
        self._emit_store_counters(stats)
        return (self._with_clients(state, clients),
                self.store.slots(np.asarray(idx)))

    def _emit_store_counters(self, stats: dict) -> None:
        """Stream one ensure call's hit/miss/evict/restore (+ prefetch,
        when enabled) deltas into telemetry (host-side only — never
        touches an RNG stream, so telemetry-on stays bit-identical to
        off)."""
        if self.telemetry is None:
            return
        for k, v in stats.items():
            self.telemetry.counter(f"client_store_{k}", int(v))

    # -- scenario plumbing (mobility / links / churn, scenarios/) ---------
    def attach_scenario(self, spec, seed: int = 0) -> None:
        """Attach an environment scenario (name or ScenarioConfig).

        For the infrastructure-based baselines the scenario contributes
        client churn (availability gates selection) and wireless round
        pricing against a central base station — they never read the
        connectivity graph, so the scenario runs in **positions-only**
        mode: mobility advances positions (identical RNG stream) but the
        O(n²) adjacency/degree/component stack is skipped entirely.
        Graph-walking trainers override this with
        :meth:`_attach_walking_scenario`.
        """
        from ..scenarios import build_scenario

        self.scenario = build_scenario(spec, self.n_clients, seed=seed,
                                       positions_only=True)
        self.scenario.telemetry = self.telemetry

    def _attach_walking_scenario(self, spec, seed: int, *,
                                 min_degree: int = 5, regen_every: int = 10,
                                 transition: str = "degree",
                                 walk_policy: str | None = None,
                                 walk_bias: float = 1.0,
                                 label_weights=None) -> None:
        """Shared attach path for the graph-walking trainers (RWSADMM,
        Walkman, fleets): build the full-stack scenario, expose it under
        the DynamicGraph contract, and reset a random-walk server on it.
        Callers that track a seed should update it before delegating.

        ``walk_policy``/``walk_bias``/``label_weights`` configure the
        importance-biased walk policies (``core.markov.WALK_POLICIES``,
        see ``docs/walks.md``); the defaults keep the walker on the
        unbiased ``transition`` chain, bit-identical to the seed path.
        """
        from ..core.markov import RandomWalkServer
        from ..scenarios import build_scenario

        self.scenario = build_scenario(
            spec, self.n_clients, seed=seed,
            min_degree=min_degree, regen_every=regen_every,
        )
        self.dyn_graph = self.scenario   # DynamicGraph-compatible facade
        self.walker = RandomWalkServer(transition=transition, seed=seed + 1,
                                       policy=walk_policy,
                                       bias_gamma=float(walk_bias))
        if label_weights is not None:
            self.walker.set_label_weights(label_weights)
        self.walker.reset(self.dyn_graph.current())
        self.scenario.telemetry = self.telemetry

    # -- static-analysis capture (repro.analysis) -------------------------
    def _audit_record(self, name: str, fn, args, kwargs=None) -> None:
        """Register one jitted closure call for the jaxpr auditor."""
        if self._audit_capture:
            self._audit_entries.append(
                (name, fn, tuple(args), dict(kwargs or {})))

    @contextlib.contextmanager
    def capture_jitted(self):
        """Arm closure capture: every jitted step call made inside the
        context is recorded as ``(name, fn, args, kwargs)`` — the jaxpr
        auditor traces these to assert the compiled-path invariants
        (no f64, no baked constants, donation, no callbacks)."""
        self._audit_capture, self._audit_entries = True, []
        try:
            yield self._audit_entries
        finally:
            self._audit_capture = False

    def set_telemetry(self, run) -> None:
        """Attach (or detach, ``None``) a ``TelemetryRun``: the trainer
        and its scenario emit phase spans / events into it. Never
        touches any RNG stream, so trajectories are unchanged."""
        self.telemetry = run
        if self.scenario is not None:
            self.scenario.telemetry = run
        if self.store is not None:
            self.store.telemetry = run

    def _phase(self, name: str, **meta):
        """A phase-timer span against the attached telemetry run, or a
        record-nowhere span when telemetry is off."""
        if self.telemetry is None:
            from ..telemetry import null_phase

            return null_phase()
        return self.telemetry.phase(name, **meta)

    def select_clients(self, rnd: int, rng: np.random.Generator,
                       m: int) -> np.ndarray:
        """Uniform client selection, churn-aware when a scenario is
        attached. Without a scenario this consumes ``rng`` exactly like
        the legacy ``rng.choice(n, m, replace=False)`` call."""
        if self.scenario is None:
            return rng.choice(self.n_clients, size=m, replace=False)
        if rnd > 0:
            self.scenario.step()
        avail = self.scenario.availability()
        pool = (np.flatnonzero(avail) if avail is not None
                else np.arange(self.n_clients))
        if len(pool) == 0:
            pool = np.arange(self.n_clients)
        # Jitted round bodies need fixed shapes: when churn leaves fewer
        # than m clients awake, fill the cohort by resampling the pool
        # (duplicates just reweight the average).
        replace = len(pool) < m
        return rng.choice(pool, size=m, replace=replace)

    def scenario_round_costs(self, members: np.ndarray) -> dict:
        """Wireless latency/energy for one baseline round (base-station
        topology); {} when no scenario is attached. Priced over all
        cohort slots — duplicates from churn resampling count as
        distinct transfers, matching comm_bytes_per_round's ledger."""
        if self.scenario is None:
            return {}
        lat, en = self.scenario.price_star_round(
            np.asarray(members), self.params_bytes())
        return {"latency_s": lat, "energy_j": en}

    # -- abstract ----------------------------------------------------------
    def init_state(self, key):  # pragma: no cover - interface
        raise NotImplementedError

    def round(self, state, rnd: int, rng: np.random.Generator):
        """One communication round. Returns (state, metrics dict)."""
        raise NotImplementedError  # pragma: no cover

    # -- communication accounting ------------------------------------------
    def params_bytes(self) -> int:
        """Bytes of one model copy (cached — init is host-side and slow).

        Deliberately n-independent: one template ``model.init``, never a
        per-client iteration, so the communication ledger works the same
        under the lazy client plane at n = 10⁶ as on the dense plane."""
        cached = getattr(self, "_params_bytes", None)
        if cached is None:
            from ..core import tree as t

            cached = t.n_bytes(self.model.init(jax.random.PRNGKey(0)))
            self._params_bytes = cached
        return cached

    def comm_bytes_per_round(self, participants: int) -> int:
        """Default: each participant downloads + uploads one model copy."""
        return int(2 * participants * self.params_bytes())
