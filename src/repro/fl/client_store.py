"""Bounded LRU client-state store — the lazy client plane's core.

RWSADMM's mobile server only ever touches the clients it walks to:
over R rounds the walker activates O(R·Z) ≪ n clients, yet the dense
plane materializes x/z pytrees and datasets for all n up front. This
store keeps a *packed* ``(capacity, …)`` device pytree plus a packed
:class:`~repro.fl.base.DeviceData` block, keyed by an id → slot index:

* first visit **materializes** a client — ADMM state rows come from the
  shared init template (dense init is identical for every client, so
  lazy init ≡ dense init bit-for-bit), dataset rows from a deterministic
  :class:`~repro.data.loader.ClientDataFactory`;
* cold clients **evict** to a host-side spill buffer (x/z rows only —
  datasets are regenerated from the factory on revisit, bit-identical
  because the factory is pure);
* revisits **restore** the spilled rows into a free slot.

The packed client-state arrays are NOT owned by the store: they live in
the (functional) trainer state and flow through ``lax.scan``. The store
owns the mapping, the LRU order, the spill buffer, and the packed data
block; :meth:`ensure` takes the current packed pytree and returns it
with restored/initialized rows written.

Bit-identity with the dense plane is by construction — identical row
values, identical gather/scatter arithmetic, exact float32 host↔device
round-trips on evict/restore — and pinned by ``tests/test_lazy_plane.py``
rather than trusted. One subtlety the working-set rule encodes:
schedules pad zones with client id 0 (mask 0), and the dense round body
still gathers id 0's row and scatter-adds masked ±0.0 into it, so the
padding id must be resident too — callers pass the raw (padded) id
arrays to :meth:`ensure`, never pre-filtered by mask.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..data.loader import ClientDataFactory
from .base import DeviceData

PyTree = Any

#: keys of the stats dict every ensure() call returns (all deltas)
STORE_COUNTERS = ("hits", "misses", "evictions", "restores")

#: extra stats keys when async prefetch is enabled (``prefetch=True``):
#: of the misses one ensure() materialized, how many were served from
#: the staging buffer (hits) vs drawn synchronously (misses). Emitted
#: as ``client_store_prefetch_{hits,misses}`` telemetry counters — only
#: when prefetch is on, so the default event stream is unchanged.
PREFETCH_COUNTERS = ("prefetch_hits", "prefetch_misses")


def _dedupe_keep_order(ids: np.ndarray) -> np.ndarray:
    """Unique ids in first-appearance order — the store's visit order
    for a batched ensure (LRU recency follows it)."""
    ids = np.asarray(ids).reshape(-1).astype(np.int64)
    _, first = np.unique(ids, return_index=True)
    return ids[np.sort(first)]


class ClientStore:
    """Bounded LRU store of per-client ADMM state + dataset rows.

    Parameters
    ----------
    factory: per-client dataset source (``rows(ids)`` in DeviceData
        column order). Its ``n_clients`` bounds the id space.
    capacity: number of resident slots. A single :meth:`ensure` call's
        working set may not exceed it (scan chunks ensure a whole
        chunk's visited set at once — size capacity ≥ the R·Z bound of
        the chunk, see docs/performance.md §7).
    prefetch: enable the async staging pipeline — :meth:`prefetch`
        materializes a predicted working set's dataset rows on a host
        thread (pure numpy factory draws) while device compute runs;
        the next :meth:`ensure` joins the thread and consumes the
        staged rows. Values are identical either way (the factory is
        pure), so prefetch-on ≡ prefetch-off bit-for-bit.
    sharding: optional ``fl.sharding.FLSharding`` — the packed data
        block (and the packed state pytree :meth:`reset` returns) get
        their leading capacity axis placed over the mesh "data" axis;
        scatter writes preserve the placement.
    """

    def __init__(self, factory: ClientDataFactory, capacity: int, *,
                 prefetch: bool = False, sharding=None):
        self.factory = factory
        self.capacity = int(capacity)
        self.n_clients = int(factory.n_clients)
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.prefetch_enabled = bool(prefetch)
        self.sharding = sharding
        self.telemetry = None   # set via the owning trainer
        self._template: PyTree | None = None
        self.data: DeviceData | None = None
        # id → slot (-1 = not resident), slot → id (-1 = free)
        self.slot_arr = np.full(self.n_clients, -1, dtype=np.int32)
        self.gid_of = np.full(self.capacity, -1, dtype=np.int64)
        self._lru: OrderedDict[int, None] = OrderedDict()
        self._free: list[int] = list(range(self.capacity - 1, -1, -1))
        self._spill: dict[int, list[np.ndarray]] = {}
        # id → staged dataset rows (one entry per DeviceData column),
        # written only by the prefetch worker, read/consumed only after
        # _join_prefetch() — the double-buffering fence.
        self._staging: dict[int, list[np.ndarray]] = {}
        self._inflight: threading.Thread | None = None
        self.counters = {k: 0 for k in self._counter_keys()}

    def _counter_keys(self) -> tuple:
        """Counter names this store tracks: prefetch pipeline counters
        exist only when the pipeline does, so prefetch-off stores keep
        the original 4-counter contract exactly."""
        return STORE_COUNTERS + (PREFETCH_COUNTERS
                                 if self.prefetch_enabled else ())

    # ------------------------------------------------------------- init --
    def reset(self, template: PyTree) -> PyTree:
        """(Re)initialize for a fresh run: remember the single-client
        init ``template`` (every client's dense init — warm: x=params,
        z=0), clear mapping/LRU/spill/counters, allocate the packed data
        block, and return the packed ``(capacity, …)`` state pytree with
        every slot pre-filled from the template."""
        self._join_prefetch()
        # Private copy: the caller's template leaves typically alias the
        # trainer state (warm init: x = server.y = params), and the
        # sharded plane's chunk closures DONATE that state — a shared
        # buffer would be deleted under the store's feet.
        self._template = jax.tree_util.tree_map(jnp.array, template)
        self.slot_arr[:] = -1
        self.gid_of[:] = -1
        self._lru.clear()
        self._free = list(range(self.capacity - 1, -1, -1))
        self._spill.clear()
        self._staging.clear()
        self.counters = {k: 0 for k in self._counter_keys()}
        f = self.factory
        feat = tuple(f.feature_shape)
        self.data = DeviceData(
            x_train=jnp.zeros((self.capacity, f.max_train) + feat,
                              jnp.float32),
            y_train=jnp.zeros((self.capacity, f.max_train), jnp.int32),
            n_train=jnp.ones((self.capacity,), jnp.int32),
            x_test=jnp.zeros((self.capacity, f.max_test) + feat,
                             jnp.float32),
            y_test=jnp.zeros((self.capacity, f.max_test), jnp.int32),
            mask_test=jnp.zeros((self.capacity, f.max_test), jnp.float32),
        )
        if self.sharding is not None:
            self.data = self.sharding.shard_rows(self.data)
            return self.sharding.shard_rows(self._packed_template())
        return self._packed_template()

    def _packed_template(self) -> PyTree:
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(jnp.asarray(l),
                                       (self.capacity,) + jnp.shape(l)),
            self._template)

    def _template_rows(self, m: int) -> PyTree:
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(jnp.asarray(l),
                                       (m,) + jnp.shape(l)),
            self._template)

    # ------------------------------------------------------ introspection --
    @property
    def resident_ids(self) -> np.ndarray:
        """Resident client ids, least- to most-recently visited."""
        return np.fromiter(self._lru.keys(), dtype=np.int64,
                           count=len(self._lru))

    @property
    def n_resident(self) -> int:
        return len(self._lru)

    @property
    def spilled_ids(self) -> np.ndarray:
        return np.array(sorted(self._spill), dtype=np.int64)

    def slots(self, ids) -> np.ndarray:
        """Translate global client ids → resident slot indices (any
        shape). Every id must be resident (``ensure`` first)."""
        ids = np.asarray(ids)
        slots = self.slot_arr[ids]
        if (slots < 0).any():
            missing = np.unique(np.asarray(ids)[slots < 0])
            raise KeyError(f"clients not resident: {missing.tolist()[:10]}")
        return slots.astype(np.int32)

    # ------------------------------------------------------------ ensure --
    def ensure(self, clients: PyTree, ids) -> tuple[PyTree, dict]:
        """Make every id in ``ids`` resident; returns the updated packed
        state pytree and this call's counter deltas.

        ``ids`` is deduplicated in first-appearance order, which becomes
        the LRU touch order (visit order ⇒ eviction order). Misses claim
        free slots first, then evict the least-recently-visited resident
        clients *outside the current working set* — their x/z rows are
        read back to the host spill buffer before the slot is reused.
        """
        if self._template is None:
            raise RuntimeError("ClientStore.reset(template) must run "
                               "before ensure() — call init_state first")
        # Double-buffering fence: any in-flight prefetch staging must
        # land before this ensure reads/consumes the staging buffer.
        self._join_prefetch()
        # No-op for device arrays; lifts numpy leaves (e.g. a state just
        # restored by checkpoint.load_pytree) so .at updates work.
        clients = jax.tree_util.tree_map(jnp.asarray, clients)
        ids = _dedupe_keep_order(ids)
        if len(ids) > self.capacity:
            raise ValueError(
                f"working set of {len(ids)} clients exceeds store "
                f"capacity {self.capacity}; raise store_capacity or "
                f"shorten the scan chunk (eval_every)")
        if len(ids) and (ids.min() < 0 or ids.max() >= self.n_clients):
            raise IndexError(f"client id out of range [0, "
                             f"{self.n_clients}): {ids.min()},{ids.max()}")
        stats = {k: 0 for k in STORE_COUNTERS}
        missing = ids[self.slot_arr[ids] < 0]
        stats["hits"] = len(ids) - len(missing)
        stats["misses"] = len(missing)
        if self.prefetch_enabled:
            staged = sum(1 for i in missing if int(i) in self._staging)
            stats["prefetch_hits"] = staged
            stats["prefetch_misses"] = len(missing) - staged
        for i in ids:
            if self.slot_arr[i] >= 0:
                self._lru.move_to_end(int(i))

        if len(missing):
            need = len(missing) - len(self._free)
            if need > 0:
                working = set(ids.tolist())
                victims = [i for i in self._lru
                           if i not in working][:need]
                assert len(victims) == need  # capacity check above
                clients = self._evict(clients, np.array(victims,
                                                        dtype=np.int64))
                stats["evictions"] = need
            slots = np.array([self._free.pop() for _ in missing],
                             dtype=np.int32)
            for i, s in zip(missing, slots):
                self.slot_arr[i] = s
                self.gid_of[s] = i
                self._lru[int(i)] = None
                self._lru.move_to_end(int(i))
            restored = np.array([i in self._spill for i in missing])
            clients = self._write_state_rows(clients, missing, slots,
                                             restored)
            stats["restores"] = int(restored.sum())
            self._write_data_rows(missing, slots)
        # Re-touch in visit order so recency reflects ``ids`` order, not
        # hit-then-miss processing order.
        for i in ids:
            self._lru.move_to_end(int(i))
        for k, v in stats.items():
            self.counters[k] += v
        return clients, stats

    # ---------------------------------------------------------- prefetch --
    def prefetch(self, ids) -> int:
        """Stage a predicted working set's dataset rows on a background
        host thread (async prefetch pipeline): the ids in ``ids`` that
        are not resident and not already staged get their factory rows
        drawn off the critical path, so the next :meth:`ensure` (which
        joins the thread first) serves them as ``prefetch_hits`` instead
        of drawing synchronously.

        Returns the number of ids handed to the worker. No-op unless
        the store was built with ``prefetch=True``. The worker touches
        only the factory (pure numpy) and the staging dict — never the
        mapping, the LRU order, the spill buffer, or device state — so
        a concurrently executing device chunk is undisturbed and the
        run's trajectory is bit-identical with prefetch off.
        """
        if not self.prefetch_enabled:
            return 0
        self._join_prefetch()          # at most one worker in flight
        ids = _dedupe_keep_order(ids)
        todo = np.array([int(i) for i in ids
                         if self.slot_arr[i] < 0
                         and int(i) not in self._staging],
                        dtype=np.int64)
        if len(todo) == 0:
            return 0
        telemetry = self.telemetry

        def work():
            def stage():
                cols = [np.asarray(c) for c in self.factory.rows(todo)]
                for k, i in enumerate(todo):
                    self._staging[int(i)] = [c[k] for c in cols]

            if telemetry is None:
                stage()
            else:
                # The span's t0/seconds place the staging work on the
                # run timeline — overlapping the scan_chunk span when
                # the pipeline works (docs/performance.md §8).
                with telemetry.phase("prefetch_stage", ids=len(todo)):
                    stage()

        self._inflight = threading.Thread(
            target=work, name="client-store-prefetch", daemon=True)
        self._inflight.start()
        return len(todo)

    def _join_prefetch(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    # ----------------------------------------------------------- internals --
    def _evict(self, clients: PyTree, victims: np.ndarray) -> PyTree:
        vslots = self.slot_arr[victims]
        rows = jax.device_get(jax.tree_util.tree_map(
            lambda l: l[jnp.asarray(vslots)], clients))
        leaves = jax.tree_util.tree_leaves(rows)
        for j, i in enumerate(victims):
            self._spill[int(i)] = [np.asarray(leaf[j]) for leaf in leaves]
            del self._lru[int(i)]
            self.slot_arr[i] = -1
        for s in vslots:
            self.gid_of[s] = -1
            self._free.append(int(s))
        return clients

    def _write_state_rows(self, clients: PyTree, ids: np.ndarray,
                          slots: np.ndarray, restored: np.ndarray) -> PyTree:
        fresh_slots = slots[~restored]
        if len(fresh_slots):
            rows = self._template_rows(len(fresh_slots))
            clients = jax.tree_util.tree_map(
                lambda l, r: l.at[jnp.asarray(fresh_slots)].set(r),
                clients, rows)
        sp_ids = ids[restored]
        if len(sp_ids):
            sp_slots = slots[restored]
            treedef = jax.tree_util.tree_structure(clients)
            stacked = [np.stack([self._spill[int(i)][j] for i in sp_ids])
                       for j in range(treedef.num_leaves)]
            rows = jax.tree_util.tree_unflatten(treedef, stacked)
            clients = jax.tree_util.tree_map(
                lambda l, r: l.at[jnp.asarray(sp_slots)].set(
                    jnp.asarray(r)),
                clients, rows)
            for i in sp_ids:
                del self._spill[int(i)]
        return clients

    def _write_data_rows(self, ids: np.ndarray, slots: np.ndarray) -> None:
        rows = self._materialize_rows(ids)
        js = jnp.asarray(slots)
        self.data = DeviceData(*[
            leaf.at[js].set(jnp.asarray(r))
            for leaf, r in zip(self.data, rows)])

    def _materialize_rows(self, ids: np.ndarray):
        """Dataset rows for ``ids`` in order — from the prefetch staging
        buffer where staged (consumed), from the factory otherwise. The
        factory is pure, so either path yields identical bytes."""
        staged = np.array([int(i) in self._staging for i in ids],
                          dtype=bool)
        if not staged.any():
            return self.factory.rows(ids)
        fresh_ids = ids[~staged]
        fresh = (self.factory.rows(fresh_ids) if len(fresh_ids)
                 else None)
        out = []
        for j in range(len(DeviceData._fields)):
            fi = iter(range(len(fresh_ids)))
            out.append(np.stack([
                self._staging[int(i)][j] if staged[k]
                else np.asarray(fresh[j])[next(fi)]
                for k, i in enumerate(ids)]))
        for i in ids[staged]:
            del self._staging[int(i)]
        return tuple(out)

    # -------------------------------------------------------- checkpointing --
    def state_dict(self) -> dict[str, np.ndarray]:
        """Host arrays capturing mapping + LRU order + spill + counters
        (the packed state pytree itself is checkpointed by the caller as
        part of the trainer state). Spilled x/z rows ride along stacked
        per leaf; ``checkpoint.save_client_store`` writes this to npz."""
        d: dict[str, np.ndarray] = {
            "gid_of": self.gid_of.copy(),
            "lru": self.resident_ids,
            "counters": np.array([self.counters[k] for k in STORE_COUNTERS],
                                 dtype=np.int64),
            "spill_ids": self.spilled_ids,
        }
        if len(self._spill):
            n_leaves = len(next(iter(self._spill.values())))
            for j in range(n_leaves):
                d[f"spill_leaf_{j}"] = np.stack(
                    [self._spill[int(i)][j] for i in d["spill_ids"]])
        return d

    def load_state_dict(self, d: dict) -> None:
        """Restore mapping/LRU/spill/counters and re-materialize the
        packed data block for resident clients (datasets are never
        spilled — the factory regenerates them bit-identically)."""
        if self._template is None:
            raise RuntimeError("reset(template) before load_state_dict "
                               "(build the store via init_state first)")
        gid_of = np.asarray(d["gid_of"], dtype=np.int64)
        if gid_of.shape != (self.capacity,):
            raise ValueError(
                f"checkpoint capacity {gid_of.shape[0]} != store "
                f"capacity {self.capacity}")
        self.gid_of = gid_of.copy()
        self.slot_arr[:] = -1
        occupied = np.flatnonzero(gid_of >= 0)
        self.slot_arr[gid_of[occupied]] = occupied.astype(np.int32)
        self._free = [int(s) for s in range(self.capacity - 1, -1, -1)
                      if gid_of[s] < 0]
        self._lru = OrderedDict((int(i), None)
                                for i in np.asarray(d["lru"]))
        # Checkpoints save the core counters only (prefetch counters
        # restart at zero — they describe a process-local pipeline).
        cnt = np.asarray(d["counters"])
        self.counters = {k: 0 for k in self._counter_keys()}
        self.counters.update(
            {k: int(cnt[j]) for j, k in enumerate(STORE_COUNTERS)})
        self._spill = {}
        spill_ids = np.asarray(d["spill_ids"], dtype=np.int64)
        for j, i in enumerate(spill_ids):
            self._spill[int(i)] = [
                np.asarray(d[key][j]) for key in sorted(
                    (k for k in d if k.startswith("spill_leaf_")),
                    key=lambda s: int(s.rsplit("_", 1)[1]))]
        if len(occupied):
            self._write_data_rows(gid_of[occupied],
                                  occupied.astype(np.int32))
