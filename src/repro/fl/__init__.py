"""Federated-learning runtime: device data layout, trainers, simulation."""
from .base import DeviceData, TrainerBase, to_device_data  # noqa: F401
from .client_store import ClientStore  # noqa: F401
from .fleet_trainer import FleetRWSADMMTrainer  # noqa: F401
from .rwsadmm_trainer import RWSADMMTrainer  # noqa: F401
from .simulation import run_simulation  # noqa: F401
