"""Fleet-RWSADMM (beyond-paper): multiple mobile servers, compiled.

The paper's scenario has ONE tactical vehicle; its §6 scalability
discussion motivates more. Here K walkers each carry their own token y_k
and run independent random walks over the same dynamic graph; every
``sync_every`` rounds the fleet rendezvouses (satellite link) and tokens
average — between syncs, communication stays strictly local/O(1) per
vehicle. Client states (x_i, z_i) are shared: a client updates against
whichever vehicle reaches it.

Two fleet modes:

* ``fleet_mode="roundrobin"`` (default) — the walkers take turns: round
  r is served by walker ``r % K`` against its own token. One wall step
  moves every walker once per K rounds, so coverage (hitting time) drops
  ~K× in wall time while per-round compute stays identical to the
  single-walker trainer. With ``n_walkers=1`` this degenerates to the
  single-walker RWSADMM trajectory exactly (pinned in tests).
* ``fleet_mode="simultaneous"`` — every wall step moves ALL K walkers
  and serves K zones at once: the masked Eq. 31 update runs vmapped over
  the walker axis through the batched multi-zone Pallas kernel
  (``engine="scan_fused"``), with deterministic conflict resolution when
  zones overlap a client (lowest walker index wins —
  ``markov.plan_fleet_zone_round``). This is the fleet's scalability
  workload: K× the zone throughput per wall step in one device program.

State layout: tokens live as ONE stacked ``(K, …)`` pytree, so walker
selection is a ``dynamic_index``, the rendezvous average is a
``jnp.mean`` over the walker axis, and the whole ``FleetState`` stays
device-resident — which is what lets ``schedule()``/``run_chunk()``
compile R fleet rounds into a single ``lax.scan`` executable
(``engine="scan" | "scan_fused"``), trajectory-identical to the eager
fleet. Effects vs a single walker: hitting time drops ~K× (coverage),
and the averaged tokens keep a consensus anchor; with sync_every → ∞
the fleet degenerates into K independent token streams.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import markov, rwsadmm
from ..core.markov import FleetZoneSchedule, RandomWalkServer
from ..core.rwsadmm import ClientState, RWSADMMHparams, ServerState
from ..kernels.rwsadmm_update import ops as fused_ops
from .base import DeviceData, sample_batch
from .rwsadmm_trainer import RWSADMMState, RWSADMMTrainer

FLEET_MODES = ("roundrobin", "simultaneous")


class FleetState(NamedTuple):
    """Fully device-resident fleet state.

    base:   clients + server bookkeeping (κ, round counter, visited);
            ``base.server.y`` mirrors the most recent active walker's
            token (walker 0's view in simultaneous mode) — evaluation
            goes through :meth:`FleetRWSADMMTrainer.personalized_params`,
            which substitutes the fleet-mean token.
    tokens: stacked ``(K, …)`` pytree — one y token per walker.
    """

    base: RWSADMMState
    tokens: Any


def _rendezvous(tokens, sync):
    """Masked fleet rendezvous: where ``sync`` > 0 every walker's token
    is replaced by the fleet mean over the stacked walker axis
    (satellite-link averaging), else pass-through. The same compiled op
    serves the eager step and the scan body, so the two engines'
    trajectories pin bit-for-bit; ``jnp.mean`` over a stacked axis is
    also walker-order invariant up to fp reduction order (tested)."""
    return jax.tree_util.tree_map(
        lambda t: jnp.where(sync > 0,
                            jnp.mean(t, axis=0, keepdims=True), t),
        tokens)


class FleetRWSADMMTrainer(RWSADMMTrainer):
    name = "rwsadmm_fleet"

    def __init__(self, model, data: DeviceData,
                 hp: RWSADMMHparams = RWSADMMHparams(), *,
                 n_walkers: int = 3, sync_every: int = 20,
                 fleet_mode: str = "roundrobin", **kw):
        self.n_walkers = int(n_walkers)
        self.sync_every = int(sync_every)
        if fleet_mode not in FLEET_MODES:
            raise ValueError(
                f"fleet_mode must be one of {'|'.join(FLEET_MODES)}, "
                f"got {fleet_mode!r}")
        self.fleet_mode = fleet_mode
        self._fleet_step_fns: dict = {}    # (mode, use_fused) -> jit step
        self._fleet_chunk_fns: dict = {}   # (mode, engine) -> jit scan
        # super().__init__ attaches the scenario, which (via our
        # attach_scenario override) also builds the walker fleet.
        super().__init__(model, data, hp, **kw)
        if self.fleet_mode == "simultaneous":
            if self.solver != "closed_form":
                raise ValueError(
                    "simultaneous fleet mode vmaps the closed-form Eq. 31 "
                    "zone update over walkers; use solver='closed_form'")
            if self.dp_clip is not None:
                raise ValueError("simultaneous fleet mode does not "
                                 "support DP uploads")

    def _reset_fleet(self) -> None:
        # Walker k's stream is seed + 1 + 10k: walker 0 replays the
        # single-walker trainer's walker (seed + 1) draw-for-draw, so an
        # n_walkers=1 fleet is trajectory-identical to RWSADMMTrainer
        # (pinned in tests); the stride keeps the streams disjoint from
        # the scenario seeds derived nearby.
        self.walkers = [RandomWalkServer(transition=self.walker.transition,
                                         seed=self._seed + 1 + 10 * k,
                                         policy=self.walker.policy,
                                         bias_gamma=self.walker.bias_gamma)
                        for k in range(self.n_walkers)]
        for w in self.walkers:
            w.set_label_weights(self.walker.label_weights)
            w.reset(self.dyn_graph.current())

    def attach_scenario(self, spec, seed: int | None = None) -> None:
        # The RWSADMM attach path (shared _attach_walking_scenario
        # helper) builds the full-stack scenario + lead walker; the
        # fleet then fans out K walkers over the same graph.
        super().attach_scenario(spec, seed=seed)
        if hasattr(self, "n_walkers"):   # re-attach after construction
            self._reset_fleet()

    def init_state(self, key) -> FleetState:
        base = super().init_state(key)
        tokens = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (self.n_walkers,) + l.shape),
            base.server.y)
        if self.fl_sharding is not None:
            # The (K, …) token stack has a walker (not client) leading
            # axis — it replicates like the single-server token.
            tokens = self.fl_sharding.replicate(tokens)
        return FleetState(base=base, tokens=tokens)

    # ------------------------------------------------------------------
    # Lazy client-plane hooks: the fleet nests the client stack/visited
    # mask one level down (state.base), and lazy evaluation measures the
    # global model against the fleet-mean token rather than one walker's.
    # ------------------------------------------------------------------
    def _state_clients(self, state):
        return state.base.clients

    def _state_visited(self, state):
        return state.base.visited

    def _with_clients(self, state, clients):
        return state._replace(base=state.base._replace(clients=clients))

    def _eval_token(self, state):
        return self.global_params(state)

    # ------------------------------------------------------------------
    # Compiled step bodies — ONE jitted function per (mode, fused) pair
    # serves both the eager driver and the lax.scan chunk body, so the
    # engines run literally the same computation per round.
    # ------------------------------------------------------------------
    def _rr_step_impl(self, state: FleetState, idx, mask, n_i, a, sync,
                      key, iw=None, gid=None, data=None, *,
                      use_fused: bool = False):
        """Round-robin fleet round: walker ``a`` serves one zone against
        its own token (dynamic_index into the stack), then an optional
        rendezvous averages the stack. ``iw`` (biased walk policies) is
        the active walker's importance weight, threaded into the shared
        Eq. 31 round body's y fold; ``gid``/``data`` thread the lazy
        client plane through it (slot-indexed zone + packed store data,
        see :meth:`RWSADMMTrainer._round_impl`)."""
        y_k = jax.tree_util.tree_map(
            lambda t: jax.lax.dynamic_index_in_dim(t, a, 0, keepdims=False),
            state.tokens)
        base = RWSADMMState(
            clients=state.base.clients,
            server=ServerState(y=y_k, kappa=state.base.server.kappa,
                               round=state.base.server.round),
            visited=state.base.visited)
        new_base, loss = self._round_impl(base, idx, mask, n_i, key, iw,
                                          gid, data,
                                          use_fused=use_fused)
        tokens = jax.tree_util.tree_map(
            lambda t, y: jax.lax.dynamic_update_index_in_dim(t, y, a, 0),
            state.tokens, new_base.server.y)
        return FleetState(base=new_base,
                          tokens=_rendezvous(tokens, sync)), loss

    def _sim_step_impl(self, state: FleetState, idx, mask, n_i, sync,
                       key, iw=None, gid=None, data=None, *,
                       use_fused: bool = False):
        """Simultaneous fleet wall step: K disjoint zones (idx/mask are
        (K, Z)) update in one vmapped Eq. 31 pass, each against its own
        walker's token; κ decays once per wall step. ``iw`` (biased walk
        policies) carries each walker's importance weight (K,); the
        per-walker token folds are rescaled by it post hoc. Lazy plane:
        ``idx`` holds store slots, ``gid`` the (K, Z) global ids, and
        ``data`` the packed store rows as a traced argument."""
        data = self.data if data is None else data
        clients = state.base.clients
        hp, kappa = self.hp, state.base.server.kappa
        k_walkers, zone = idx.shape
        gather = lambda t: jax.tree_util.tree_map(lambda l: l[idx], t)
        act = ClientState(x=gather(clients.x), z=gather(clients.z))
        keys = jax.random.split(key, k_walkers * zone).reshape(
            k_walkers, zone, -1)

        def one_grad(params, client, kk):
            xb, yb = sample_batch(data, client, kk, self.batch_size)
            return self.value_and_grad_fn(params, xb, yb, kk)

        losses, grads = jax.vmap(jax.vmap(one_grad))(act.x, idx, keys)
        if use_fused:
            # All K zones' Eq. 31 triple updates in ONE kernel launch.
            x_f, z_f, y_new = fused_ops.rwsadmm_multizone_fused_update(
                act.x, act.z, state.tokens, grads, mask, kappa,
                beta=hp.beta, eps_half=hp.eps_half,
                n_total=float(self.n_clients))
            new_act = ClientState(x=x_f, z=z_f)
        else:
            new_act, y_new = rwsadmm.multizone_round_masked(
                act, state.tokens, grads, mask, hp, kappa,
                float(self.n_clients))
        if iw is not None:
            # Walk-for-Learning correction per walker: rescale each
            # token's zone fold by its walker's importance weight.
            y_new = jax.tree_util.tree_map(
                lambda y0, y1: y0 + iw.reshape(
                    (-1,) + (1,) * (y1.ndim - 1)) * (y1 - y0),
                state.tokens, y_new)

        # Scatter all K zones back in one add: the planner guarantees
        # the zones are disjoint, padded slots carry zero deltas.
        idx_f = idx.reshape(-1)
        m_f = mask.reshape(-1)

        def scatter(full, old_l, new_l):
            fo = old_l.reshape((-1,) + old_l.shape[2:])
            fn = new_l.reshape((-1,) + new_l.shape[2:])
            mm = m_f.reshape((-1,) + (1,) * (fn.ndim - 1))
            return full.at[idx_f].add(mm * (fn - fo))

        clients = ClientState(
            x=jax.tree_util.tree_map(scatter, clients.x, act.x, new_act.x),
            z=jax.tree_util.tree_map(scatter, clients.z, act.z, new_act.z))
        tokens = _rendezvous(y_new, sync)
        server = ServerState(
            y=jax.tree_util.tree_map(lambda t: t[0], tokens),
            kappa=kappa * hp.kappa_decay,
            round=state.base.server.round + 1)
        visited = state.base.visited.at[
            idx_f if gid is None else gid.reshape(-1)].max(m_f > 0)
        loss = jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return FleetState(base=RWSADMMState(clients, server, visited),
                          tokens=tokens), loss

    def _fleet_step_fn(self, mode: str, use_fused: bool):
        fn = self._fleet_step_fns.get((mode, use_fused))
        if fn is None:
            impl = (self._rr_step_impl if mode == "roundrobin"
                    else self._sim_step_impl)
            # named partial: compile logs + the analysis compile-budget
            # sentinel key counts by jit(<closure name>)
            step = functools.partial(impl, use_fused=use_fused)
            functools.update_wrapper(step, impl)
            fn = jax.jit(step)
            self._fleet_step_fns[(mode, use_fused)] = fn
        return fn

    # ------------------------------------------------------------------
    # Eager driver.
    # ------------------------------------------------------------------
    def round(self, state: FleetState, rnd: int, rng: np.random.Generator):
        if self.fleet_mode == "simultaneous":
            return self._round_simultaneous(state, rnd, rng)
        k = rnd % self.n_walkers
        graph = (self.dyn_graph.step() if rnd >= self.n_walkers
                 else self.dyn_graph.current())
        walker = self.walkers[k]
        i_k = walker.step(graph) if rnd >= self.n_walkers \
            else walker.position
        idx, mask, n_i = markov.plan_zone_round(
            graph, int(i_k), self.zone_size, rng,
            avail=self.scenario.availability())
        n_active = int(mask.sum())
        latency_s, energy_j = self._price(graph, i_k, idx, mask)
        key = markov.round_key(rng)
        sync = float((rnd + 1) % max(self.sync_every, 1) == 0)
        kwargs = {}
        if self.store is not None:
            state, zone_idx = self._ensure_round(state, idx)
            kwargs = {"gid": jnp.asarray(idx), "data": self.store.data}
        else:
            zone_idx = idx
        args = [state, jnp.asarray(zone_idx), jnp.asarray(mask),
                jnp.asarray(float(n_i)), jnp.asarray(k, jnp.int32),
                jnp.asarray(sync, jnp.float32), key]
        if self._use_iw:
            args.append(jnp.asarray(walker.weight_history[-1],
                                    jnp.float32))
        step_fn = self._fleet_step_fn("roundrobin", False)
        self._audit_record("round:roundrobin", step_fn, args, kwargs)
        state, zone_loss = step_fn(*args, **kwargs)
        metrics = {
            "round": rnd, "walker": k, "client": int(i_k),
            "zone": n_active, "n_i": int(n_i),
            "train_loss": float(zone_loss),
            "kappa": float(state.base.server.kappa),
            "comm_bytes": self.comm_bytes_per_round(n_active),
            "latency_s": latency_s,
            "energy_j": energy_j,
            **self._staleness_metrics(idx, mask, rnd),
        }
        return state, metrics

    def _round_simultaneous(self, state: FleetState, rnd: int,
                            rng: np.random.Generator):
        graph = (self.dyn_graph.step() if rnd > 0
                 else self.dyn_graph.current())
        if rnd > 0:
            positions = np.array([w.step(graph) for w in self.walkers])
        else:
            positions = np.array([w.position for w in self.walkers])
        idx, mask, n_i = markov.plan_fleet_zone_round(
            graph, positions, self.zone_size, rng,
            avail=self.scenario.availability())
        key = markov.round_key(rng)
        sync = float((rnd + 1) % max(self.sync_every, 1) == 0)
        kwargs = {}
        if self.store is not None:
            state, zone_idx = self._ensure_round(state, idx)
            kwargs = {"gid": jnp.asarray(idx), "data": self.store.data}
        else:
            zone_idx = idx
        args = [state, jnp.asarray(zone_idx), jnp.asarray(mask),
                jnp.asarray(n_i), jnp.asarray(sync, jnp.float32), key]
        if self._use_iw:
            args.append(jnp.asarray(
                np.array([w.weight_history[-1] for w in self.walkers]),
                jnp.float32))
        step_fn = self._fleet_step_fn("simultaneous", False)
        self._audit_record("round:simultaneous", step_fn, args, kwargs)
        state, loss = step_fn(*args, **kwargs)
        lat_kw, en_kw = self._price_fleet_schedule(
            [graph], positions[None], idx[None], mask[None])
        active = mask.sum(axis=1).astype(int)
        metrics = {
            "round": rnd,
            "clients": tuple(int(c) for c in positions),
            "zone": int(active.sum()), "n_i": int(n_i.sum()),
            "train_loss": float(loss),
            "kappa": float(state.base.server.kappa),
            # idle walkers (all-padding zone: every client claimed by an
            # earlier walker) transmit nothing — the wireless ledger
            # already prices them at zero, so the byte ledger agrees.
            "comm_bytes": int(sum(self.comm_bytes_per_round(int(a))
                                  for a in active if a)),
            "latency_s": float(lat_kw.max()),   # zones served in parallel
            "energy_j": float(en_kw.sum()),
            **self._staleness_metrics(idx, mask, rnd),
        }
        return state, metrics

    # ------------------------------------------------------------------
    # Compiled multi-round (lax.scan) driver.
    # ------------------------------------------------------------------
    def _price_fleet_schedule(self, graphs, clients, idx, mask):
        """Per-walker pricing of a simultaneous window: (R, K) columns."""
        return self.scenario.price_fleet_schedule(
            graphs, clients, idx, mask, self.params_bytes())

    def schedule(self, rounds: int, rng: np.random.Generator,
                 *, start_round: int = 0) -> FleetZoneSchedule:
        """Precompute ``rounds`` fleet rounds (active walker, per-walker
        positions, zone plan(s), sync mask, keys, pricing) consuming the
        graph/walker/sim RNGs exactly as the eager fleet driver would."""
        return markov.fleet_zone_schedule(
            self.dyn_graph, self.walkers, rounds, self.zone_size, rng,
            start_round=start_round, sync_every=self.sync_every,
            mode=self.fleet_mode, price=self._price_schedule,
            price_fleet=self._price_fleet_schedule,
            batched_walk=self.batched_walk)

    def run_chunk(self, state: FleetState, sched: FleetZoneSchedule,
                  engine: str = "scan"):
        """Run a whole fleet schedule chunk as ONE compiled ``lax.scan``
        (round-robin: per-round walker index + sync flag ride along as
        scan inputs; simultaneous: the walker axis rides inside idx/mask).
        Returns (state, {"train_loss": (R,), "kappa": (R,)})."""
        use_fused = self._engine_use_fused(engine)
        mode = getattr(sched, "mode", "roundrobin")
        lazy = self.store is not None
        if lazy:
            # Chunk visited set (both fleet modes' idx layouts flatten
            # the same way) resident before the scan; ids pre-translated
            # to slots, global ids ride along for the visited update.
            with self._phase("ensure", rounds=int(sched.rounds)):
                state, slot_idx = self._ensure_round(state, sched.idx)
        fn = self._fleet_chunk_fns.get((mode, engine))
        if fn is None:
            step = functools.partial(
                self._rr_step_impl if mode == "roundrobin"
                else self._sim_step_impl,
                use_fused=use_fused)
            use_iw = self._use_iw
            if mode == "roundrobin" and lazy:
                def chunk(state, data, idx, gidx, mask, n_i, keys,
                          walker, sync, iws=None):
                    def body(carry, per):
                        i_r, g_r, m_r, ni_r, k_r, a_r, s_r = per[:7]
                        w_r = per[7] if use_iw else None
                        new_state, loss = step(carry, i_r, m_r, ni_r,
                                               a_r, s_r, k_r, w_r,
                                               gid=g_r, data=data)
                        return new_state, (loss,
                                           new_state.base.server.kappa)

                    cols = (idx, gidx, mask, n_i, keys, walker, sync)
                    if use_iw:
                        cols = cols + (iws,)
                    return jax.lax.scan(body, state, cols)
            elif mode == "roundrobin":
                def chunk(state, idx, mask, n_i, keys, walker, sync,
                          iws=None):
                    def body(carry, per):
                        i_r, m_r, ni_r, k_r, a_r, s_r = per[:6]
                        w_r = per[6] if use_iw else None
                        new_state, loss = step(carry, i_r, m_r, ni_r,
                                               a_r, s_r, k_r, w_r)
                        return new_state, (loss,
                                           new_state.base.server.kappa)

                    cols = (idx, mask, n_i, keys, walker, sync)
                    if use_iw:
                        cols = cols + (iws,)
                    return jax.lax.scan(body, state, cols)
            elif lazy:
                def chunk(state, data, idx, gidx, mask, n_i, keys, sync,
                          iws=None):
                    def body(carry, per):
                        i_r, g_r, m_r, ni_r, k_r, s_r = per[:6]
                        w_r = per[6] if use_iw else None
                        new_state, loss = step(carry, i_r, m_r, ni_r,
                                               s_r, k_r, w_r,
                                               gid=g_r, data=data)
                        return new_state, (loss,
                                           new_state.base.server.kappa)

                    cols = (idx, gidx, mask, n_i, keys, sync)
                    if use_iw:
                        cols = cols + (iws,)
                    return jax.lax.scan(body, state, cols)
            else:
                def chunk(state, idx, mask, n_i, keys, sync, iws=None):
                    def body(carry, per):
                        i_r, m_r, ni_r, k_r, s_r = per[:5]
                        w_r = per[5] if use_iw else None
                        new_state, loss = step(carry, i_r, m_r, ni_r,
                                               s_r, k_r, w_r)
                        return new_state, (loss,
                                           new_state.base.server.kappa)

                    cols = (idx, mask, n_i, keys, sync)
                    if use_iw:
                        cols = cols + (iws,)
                    return jax.lax.scan(body, state, cols)
            if self.fl_sharding is not None:
                # Sharded plane: donate the chunk carry (see the base
                # trainer's run_chunk) — opt-in, default path unchanged.
                fn = jax.jit(chunk, donate_argnums=(0,))
            else:
                fn = jax.jit(chunk)
            self._fleet_chunk_fns[(mode, engine)] = fn

        args = []
        if lazy:
            args += [self.store.data, jnp.asarray(slot_idx),
                     jnp.asarray(sched.idx)]
        else:
            args.append(jnp.asarray(sched.idx))
        args += [jnp.asarray(sched.mask), jnp.asarray(sched.n_i),
                 jnp.asarray(sched.keys)]
        if mode == "roundrobin":
            args.append(jnp.asarray(sched.walker))
        args.append(jnp.asarray(sched.sync))
        if self._use_iw:
            args.append(jnp.asarray(sched.iw, jnp.float32))
        self._audit_record(f"chunk:{mode}:{engine}", fn, [state] + args)
        final, (losses, kappas) = fn(state, *args)
        self._chunk_shapes.add((engine, sched.rounds))
        return final, {"train_loss": losses, "kappa": kappas}

    def chunk_round_metrics(self, sched: FleetZoneSchedule, stacked: dict,
                            start_round: int) -> list[dict]:
        if getattr(sched, "mode", "roundrobin") == "roundrobin":
            entries = super().chunk_round_metrics(sched, stacked,
                                                  start_round)
            for j, entry in enumerate(entries):
                entry["walker"] = int(sched.walker[j])
            return entries
        losses = np.asarray(stacked["train_loss"])
        kappas = np.asarray(stacked["kappa"])
        out = []
        for j in range(sched.rounds):
            per_active = np.asarray(sched.active[j])       # (K,)
            entry = {
                "round": start_round + j,
                "clients": tuple(int(c) for c in sched.clients[j]),
                "zone": int(per_active.sum()),
                "n_i": int(np.asarray(sched.n_i[j]).sum()),
                "train_loss": float(losses[j]),
                "kappa": float(kappas[j]),
                "comm_bytes": int(sum(self.comm_bytes_per_round(int(a))
                                      for a in per_active if a)),
            }
            if sched.latency_s is not None:
                entry["latency_s"] = float(sched.latency_s[j])
                entry["energy_j"] = float(sched.energy_j[j])
            entry.update(self._staleness_metrics(
                sched.idx[j], sched.mask[j], start_round + j))
            out.append(entry)
        return out

    # ------------------------------------------------------------------
    def personalized_params(self, state: FleetState):
        """Visited clients keep their x_i; unvisited clients fall back to
        the fleet-mean token (what a rendezvous would hand them)."""
        base = state.base._replace(
            server=state.base.server._replace(y=self.global_params(state)))
        return super().personalized_params(base)

    def global_params(self, state: FleetState):
        return jax.tree_util.tree_map(lambda t: jnp.mean(t, axis=0),
                                      state.tokens)

    def fleet_hitting_time(self) -> int | None:
        """WALL-CLOCK steps until the union of walker visits covers all
        clients (the K vehicles move simultaneously in the field, so one
        wall step = one move of every walker — the fleet's coverage
        advantage is ≈K× in wall time, not in total rounds)."""
        counts = sum(w.visit_counts for w in self.walkers
                     if w.visit_counts is not None)
        if counts is None or (counts == 0).any():
            return None
        seen: set[int] = set()
        hists = [w.history for w in self.walkers]
        for step in range(max(len(h) for h in hists)):
            for h in hists:
                if step < len(h):
                    seen.add(h[step])
            if len(seen) == self.n_clients:
                return step
        return None
