"""Fleet-RWSADMM (beyond-paper): multiple mobile servers.

The paper's scenario has ONE tactical vehicle; its §6 scalability
discussion motivates more. Here K walkers each carry their own token y_k
and run independent random walks over the same dynamic graph; every
``sync_every`` rounds the fleet rendezvouses (satellite link) and tokens
average — between syncs, communication stays strictly local/O(1) per
vehicle. Client states (x_i, z_i) are shared: a client updates against
whichever vehicle reaches it.

Effects vs a single walker: hitting time drops ~K× (coverage), and the
averaged tokens keep a consensus anchor; with sync_every → ∞ the fleet
degenerates into K independent federations.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import markov
from ..core.markov import RandomWalkServer
from ..core.rwsadmm import RWSADMMHparams, ServerState
from .base import DeviceData
from .rwsadmm_trainer import RWSADMMState, RWSADMMTrainer


class FleetState(NamedTuple):
    base: RWSADMMState          # clients + ACTIVE walker's server view
    tokens: tuple               # per-walker y pytrees
    kappa: jnp.ndarray


class FleetRWSADMMTrainer(RWSADMMTrainer):
    name = "rwsadmm_fleet"

    def __init__(self, model, data: DeviceData,
                 hp: RWSADMMHparams = RWSADMMHparams(), *,
                 n_walkers: int = 3, sync_every: int = 20, **kw):
        self.n_walkers = int(n_walkers)
        self.sync_every = int(sync_every)
        # super().__init__ attaches the scenario, which (via our
        # attach_scenario override) also builds the walker fleet.
        super().__init__(model, data, hp, **kw)

    def _reset_fleet(self) -> None:
        self.walkers = [RandomWalkServer(transition=self.walker.transition,
                                         seed=self._seed + 10 + k)
                        for k in range(self.n_walkers)]
        for w in self.walkers:
            w.reset(self.dyn_graph.current())

    def attach_scenario(self, spec, seed: int | None = None) -> None:
        # The RWSADMM attach path (shared _attach_walking_scenario
        # helper) builds the full-stack scenario + lead walker; the
        # fleet then fans out K walkers over the same graph.
        super().attach_scenario(spec, seed=seed)
        if hasattr(self, "n_walkers"):   # re-attach after construction
            self._reset_fleet()

    def init_state(self, key) -> FleetState:
        base = super().init_state(key)
        tokens = tuple(base.server.y for _ in range(self.n_walkers))
        return FleetState(base=base, tokens=tokens,
                          kappa=base.server.kappa)

    def round(self, state: FleetState, rnd: int, rng: np.random.Generator):
        k = rnd % self.n_walkers
        graph = (self.dyn_graph.step() if rnd >= self.n_walkers
                 else self.dyn_graph.current())
        walker = self.walkers[k]
        i_k = walker.step(graph) if rnd >= self.n_walkers \
            else walker.position
        idx, mask, n_i = markov.plan_zone_round(
            graph, int(i_k), self.zone_size, rng,
            avail=self.scenario.availability())
        n_active = int(mask.sum())
        latency_s, energy_j = self._price(graph, i_k, idx, mask)

        # run the zone step against walker k's token
        base = RWSADMMState(
            clients=state.base.clients,
            server=ServerState(y=state.tokens[k], kappa=state.kappa,
                               round=state.base.server.round),
            visited=state.base.visited,
        )
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        base, zone_loss = self._round_fn(
            base, jnp.asarray(idx), jnp.asarray(mask),
            jnp.asarray(float(n_i)), key)
        tokens = list(state.tokens)
        tokens[k] = base.server.y

        # fleet rendezvous: average the tokens
        if (rnd + 1) % self.sync_every == 0:
            mean = jax.tree_util.tree_map(
                lambda *ls: sum(ls) / len(ls), *tokens)
            tokens = [mean for _ in tokens]

        metrics = {
            "round": rnd, "walker": k, "client": int(i_k),
            "zone": n_active,
            "train_loss": float(zone_loss),
            "comm_bytes": self.comm_bytes_per_round(n_active),
            "latency_s": latency_s,
            "energy_j": energy_j,
        }
        return FleetState(base=base, tokens=tuple(tokens),
                          kappa=base.server.kappa), metrics

    # The fleet round interleaves K walkers and host-side token averaging;
    # the single-walker schedule/run_chunk drivers do not model that.
    def schedule(self, *args, **kwargs):
        raise NotImplementedError(
            "FleetRWSADMMTrainer has per-walker host state; "
            "use engine='eager'")

    def run_chunk(self, *args, **kwargs):
        raise NotImplementedError(
            "FleetRWSADMMTrainer has per-walker host state; "
            "use engine='eager'")

    def personalized_params(self, state: FleetState):
        return super().personalized_params(state.base)

    def global_params(self, state: FleetState):
        return jax.tree_util.tree_map(
            lambda *ls: sum(ls) / len(ls), *state.tokens)

    def fleet_hitting_time(self) -> int | None:
        """WALL-CLOCK steps until the union of walker visits covers all
        clients (the K vehicles move simultaneously in the field, so one
        wall step = one move of every walker — the fleet's coverage
        advantage is ≈K× in wall time, not in total rounds)."""
        counts = sum(w.visit_counts for w in self.walkers
                     if w.visit_counts is not None)
        if counts is None or (counts == 0).any():
            return None
        seen: set[int] = set()
        hists = [w.history for w in self.walkers]
        for step in range(max(len(h) for h in hists)):
            for h in hists:
                if step < len(h):
                    seen.add(h[step])
            if len(seen) == self.n_clients:
                return step
        return None
