"""Generic FL simulation runner: drives any trainer for R rounds, records
convergence history, communication totals, and wall time.

Two execution engines:

* ``engine="eager"`` (default, any trainer): one ``trainer.round`` call —
  i.e. one XLA dispatch plus one blocking host sync — per round.
* ``engine="scan" | "scan_fused"`` (trainers exposing ``schedule`` /
  ``run_chunk``, currently RWSADMM): the random-walk / zone schedule for a
  whole eval window is precomputed host-side, then the window runs as ONE
  compiled ``lax.scan`` executable; per-round metrics come back as stacked
  arrays with a single device→host sync per window. Same trajectories as
  eager (the schedule replays the eager driver's RNG draws), minus the
  per-round dispatch overhead that dominates wall-clock for small models.

Both engines emit ``round_metrics`` under one schema
(``fl.base.normalize_round_metrics`` / ``validate_round_metrics``):
every entry has at least ``round`` and ``comm_bytes``, plus whatever the
trainer adds (``train_loss``, ``kappa``, wireless ``latency_s`` /
``energy_j`` from the scenario subsystem, …) — key sets are identical
between engines for the same trainer (asserted in
``tests/test_scan_driver.py``).

``scenario=`` overrides the trainer's environment (a name from the
``scenarios`` registry or a ``ScenarioConfig``) before the run starts.

``telemetry=`` (a ``repro.telemetry.TelemetryRun``, default ``None``)
records the run: manifest config, per-round ``round`` events, the
walk/zone ``visit`` trace, eval ``snapshot`` events, and fenced
``phase`` spans (``schedule`` / ``scan_chunk`` / ``eval`` /
``round_eager``). Telemetry never touches an RNG stream or adds device
syncs beyond the fences the drivers already imply, so telemetry-on
trajectories are bit-identical to telemetry-off (pinned in
``tests/test_telemetry.py``). Render a recorded run with
``python -m repro.telemetry.report runs/<id>``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from ..telemetry import (
    maybe_trace,
    telemetry_print,
    visit_events_from_round,
    visit_events_from_schedule,
)
from .base import TrainerBase, normalize_round_metrics


@dataclasses.dataclass
class SimulationResult:
    algo: str
    history: list[dict]             # eval snapshots (sparse, every eval_every)
    round_metrics: list[dict]       # per-round metrics (train loss etc.)
    final: dict                     # last eval snapshot
    total_comm_bytes: int
    wall_time_s: float
    total_latency_s: float = 0.0    # wireless cost totals (0 when the
    total_energy_j: float = 0.0     # trainer prices no scenario comm)

    def curve(self, key: str = "acc") -> tuple[np.ndarray, np.ndarray]:
        rounds = np.array([h["round"] for h in self.history])
        vals = np.array([h.get(key, np.nan) for h in self.history])
        return rounds, vals


def _snapshot(trainer, state, rnd: int, total_comm: int,
              history: list[dict], verbose: bool, tag: str,
              telemetry=None) -> None:
    """Eval the current state and append the snapshot (shared by both
    engines so the history shape can never diverge between them)."""
    with trainer._phase("eval", round=rnd):
        snap = trainer.evaluate(state)
    snap["round"] = rnd
    snap["comm_bytes_total"] = total_comm
    history.append(snap)
    if telemetry is not None:
        telemetry.snapshot(snap)
    if verbose:
        # Not every trainer evaluates accuracy (eval-disabled baselines
        # omit "acc" entirely) — format what the snapshot actually has.
        acc = snap.get("acc")
        acc_s = f"acc={acc:.4f}  " if acc is not None else ""
        telemetry_print(f"[{tag}] round {rnd:4d}  {acc_s}"
                        f"comm={total_comm / 1e6:.1f}MB")


def _result(trainer, history, round_metrics, total_comm,
            wall: float) -> SimulationResult:
    return SimulationResult(
        algo=trainer.name,
        history=history,
        round_metrics=round_metrics,
        final=history[-1] if history else {},
        total_comm_bytes=total_comm,
        wall_time_s=wall,
        total_latency_s=float(sum(m.get("latency_s", 0.0)
                                  for m in round_metrics)),
        total_energy_j=float(sum(m.get("energy_j", 0.0)
                                 for m in round_metrics)),
    )


def _finalize_telemetry(telemetry, result: SimulationResult) -> None:
    telemetry.counter("total_comm_bytes", result.total_comm_bytes)
    telemetry.counter("total_latency_s", result.total_latency_s)
    telemetry.counter("total_energy_j", result.total_energy_j)
    telemetry.counter("wall_time_s", round(result.wall_time_s, 6))


def run_simulation(
    trainer: TrainerBase,
    *,
    rounds: int = 100,
    eval_every: int = 10,
    seed: int = 0,
    verbose: bool = False,
    engine: str = "eager",
    scenario=None,
    telemetry=None,
) -> SimulationResult:
    if scenario is not None:
        trainer.attach_scenario(scenario, seed=seed)
    if telemetry is not None:
        trainer.set_telemetry(telemetry)
        telemetry.update_manifest(config={
            "algo": trainer.name, "engine": engine, "rounds": rounds,
            "eval_every": eval_every, "sim_seed": seed,
            "n_clients": trainer.n_clients,
        })
        if telemetry.manifest.get("seed") is None:
            telemetry.update_manifest(seed=seed)
    if engine != "eager":
        return _run_simulation_scan(
            trainer, rounds=rounds, eval_every=eval_every, seed=seed,
            verbose=verbose, engine=engine, telemetry=telemetry,
        )
    rng = np.random.default_rng(seed)
    with trainer._phase("init_state") as sp:
        state = trainer.init_state(jax.random.PRNGKey(seed))
        if telemetry is not None:
            sp.fence(state)
    history: list[dict] = []
    round_metrics: list[dict] = []
    total_comm = 0
    t0 = time.perf_counter()
    with maybe_trace(telemetry):
        for r in range(rounds):
            with trainer._phase("round_eager", round=r):
                state, metrics = trainer.round(state, r, rng)
            metrics = normalize_round_metrics(metrics, r)
            total_comm += int(metrics["comm_bytes"])
            round_metrics.append(metrics)
            if telemetry is not None:
                telemetry.round(metrics)
                for v in visit_events_from_round(metrics):
                    telemetry.visit(**v)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                _snapshot(trainer, state, r + 1, total_comm, history,
                          verbose, trainer.name, telemetry)
    wall = time.perf_counter() - t0
    result = _result(trainer, history, round_metrics, total_comm, wall)
    if telemetry is not None:
        _finalize_telemetry(telemetry, result)
    return result


def _run_simulation_scan(
    trainer: Any,
    *,
    rounds: int,
    eval_every: int,
    seed: int,
    verbose: bool,
    engine: str,
    telemetry=None,
) -> SimulationResult:
    """Chunked scan driver: one compiled executable per eval window."""
    if not (hasattr(trainer, "schedule") and hasattr(trainer, "run_chunk")
            and hasattr(trainer, "chunk_round_metrics")):
        raise ValueError(
            f"trainer {trainer.name!r} has no scan driver (needs "
            ".schedule/.run_chunk/.chunk_round_metrics); "
            "use engine='eager'")
    rng = np.random.default_rng(seed)
    with trainer._phase("init_state") as sp:
        state = trainer.init_state(jax.random.PRNGKey(seed))
        if telemetry is not None:
            sp.fence(state)
    history: list[dict] = []
    round_metrics: list[dict] = []
    total_comm = 0
    # Async prefetch (lazy plane, opt-in): while one window's compiled
    # scan executes on device, the host precomputes the NEXT window's
    # schedule and hands its ids to the store's staging thread, so the
    # following ensure() starts from pre-materialized rows. Schedule
    # draws stay in exactly the same rng order (windows are scheduled
    # strictly left to right; metrics/eval consume no rng), so
    # prefetch-on trajectories are bit-identical to prefetch-off
    # (pinned in tests/test_lazy_plane.py).
    prefetching = (getattr(trainer, "store", None) is not None
                   and trainer.store.prefetch_enabled
                   and hasattr(trainer, "prefetch_chunk"))
    sched = None
    t0 = time.perf_counter()
    r = 0
    with maybe_trace(telemetry):
        while r < rounds:
            # Align chunks to eval boundaries so snapshots land on the
            # same rounds as the eager driver.
            r_next = min(((r // eval_every) + 1) * eval_every, rounds)
            if sched is None:   # not handed over by a prefetch iteration
                with trainer._phase("schedule", round=r,
                                    chunk_rounds=r_next - r):
                    sched = trainer.schedule(r_next - r, rng,
                                             start_round=r)
            sched_next = None
            with trainer._phase("scan_chunk", round=r, engine=engine,
                                chunk_rounds=r_next - r,
                                includes_compile=trainer.chunk_is_cold(
                                    engine, r_next - r)) as sp:
                state, stacked = trainer.run_chunk(state, sched,
                                                   engine=engine)
                if prefetching and r_next < rounds:
                    # The chunk is dispatched (async) — overlap the next
                    # window's host work behind it, then fence.
                    r_nn = min(((r_next // eval_every) + 1) * eval_every,
                               rounds)
                    with trainer._phase("schedule", round=r_next,
                                        chunk_rounds=r_nn - r_next):
                        sched_next = trainer.schedule(
                            r_nn - r_next, rng, start_round=r_next)
                    trainer.prefetch_chunk(sched_next)
                if telemetry is not None:
                    sp.fence((state, stacked))
            # The trainer rebuilds the per-round metric entries (one
            # device→host sync per window): single-walker and fleet
            # schedules carry different columns (active walker, K zones,
            # per-walker pricing), so the schema lives with the trainer.
            entries = [normalize_round_metrics(e, r + j) for j, e in
                       enumerate(trainer.chunk_round_metrics(sched,
                                                             stacked, r))]
            for entry in entries:
                total_comm += int(entry["comm_bytes"])
                round_metrics.append(entry)
            if telemetry is not None:
                # Walk/zone trace: one vectorized pass over the chunk's
                # already-materialized host schedule arrays.
                for entry in entries:
                    telemetry.round(entry)
                for v in visit_events_from_schedule(sched, r, entries):
                    telemetry.visit(**v)
            r = r_next
            sched = sched_next
            if r % eval_every == 0 or r == rounds:
                _snapshot(trainer, state, r, total_comm, history, verbose,
                          f"{trainer.name}/{engine}", telemetry)
    wall = time.perf_counter() - t0
    result = _result(trainer, history, round_metrics, total_comm, wall)
    if telemetry is not None:
        _finalize_telemetry(telemetry, result)
    return result
