"""Generic FL simulation runner: drives any trainer for R rounds, records
convergence history, communication totals, and wall time."""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from .base import TrainerBase


@dataclasses.dataclass
class SimulationResult:
    algo: str
    history: list[dict]             # eval snapshots (sparse, every eval_every)
    round_metrics: list[dict]       # per-round metrics (train loss etc.)
    final: dict                     # last eval snapshot
    total_comm_bytes: int
    wall_time_s: float

    def curve(self, key: str = "acc") -> tuple[np.ndarray, np.ndarray]:
        rounds = np.array([h["round"] for h in self.history])
        vals = np.array([h.get(key, np.nan) for h in self.history])
        return rounds, vals


def run_simulation(
    trainer: TrainerBase,
    *,
    rounds: int = 100,
    eval_every: int = 10,
    seed: int = 0,
    verbose: bool = False,
) -> SimulationResult:
    rng = np.random.default_rng(seed)
    state = trainer.init_state(jax.random.PRNGKey(seed))
    history: list[dict] = []
    round_metrics: list[dict] = []
    total_comm = 0
    t0 = time.perf_counter()
    for r in range(rounds):
        state, metrics = trainer.round(state, r, rng)
        total_comm += int(metrics.get("comm_bytes", 0))
        round_metrics.append(metrics)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            snap = trainer.evaluate(state)
            snap["round"] = r + 1
            snap["comm_bytes_total"] = total_comm
            history.append(snap)
            if verbose:
                print(
                    f"[{trainer.name}] round {r + 1:4d}  "
                    f"acc={snap['acc']:.4f}  comm={total_comm / 1e6:.1f}MB"
                )
    wall = time.perf_counter() - t0
    return SimulationResult(
        algo=trainer.name,
        history=history,
        round_metrics=round_metrics,
        final=history[-1] if history else {},
        total_comm_bytes=total_comm,
        wall_time_s=wall,
    )
