"""Qwen2-7B [arXiv:2407.10671] — dense decoder, GQA (28q/4kv), QKV bias."""
from .base import ModelConfig, register

QWEN2_7B = register(ModelConfig(
    arch_id="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    layer_pattern=("attn",),
    qkv_bias=True,
    rope="standard",
    rope_theta=1e6,
    act="silu",
    source="arXiv:2407.10671",
))
