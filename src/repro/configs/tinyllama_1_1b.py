"""TinyLlama-1.1B [arXiv:2401.02385] — llama2-arch small, GQA (32q/4kv)."""
from .base import ModelConfig, register

TINYLLAMA_1_1B = register(ModelConfig(
    arch_id="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    layer_pattern=("attn",),
    rope="standard",
    rope_theta=1e4,
    act="silu",
    source="arXiv:2401.02385",
))
