"""RecurrentGemma-9B [arXiv:2402.19427] — Griffin hybrid: RG-LRU recurrent
blocks + local sliding-window attention at 2:1 (attention every third
layer), MQA (kv=1), window 2048. 38 layers = (r,r,l)×12 + (r,r):
implemented as a 19-layer pattern repeated twice."""
from .base import ModelConfig, register

_PATTERN = (("rglru", "rglru", "local") * 6 + ("rglru",))  # len 19, ×2 = 38

RECURRENTGEMMA_9B = register(ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    layer_pattern=_PATTERN,
    window=2048,
    rope="standard",
    rope_theta=1e4,
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2402.19427",
))
