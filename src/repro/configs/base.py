"""Architecture config system.

Every assigned architecture gets a ``ModelConfig`` in its own module
(``repro/configs/<arch>.py``) with the exact shapes from the assignment
(source papers/model cards cited per config). ``reduced()`` derives the
smoke-test variant (≤2 layers, d_model ≤ 512, ≤4 experts) of the same
family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    capacity_factor: float = 1.25
    n_shared_experts: int = 0   # always-on experts (DeepSeek/Kimi style)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""

    # Layer mixing: the repeating unit of layer kinds; n_layers must be a
    # multiple of len(layer_pattern). Kinds: "attn" (global), "local"
    # (sliding window), "rglru" (Griffin recurrent), "mlstm", "slstm".
    layer_pattern: tuple[str, ...] = ("attn",)
    window: int = 4096           # sliding-window size for "local" layers

    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope: str = "standard"       # standard | mrope | none
    rope_theta: float = 1e4
    moe: Optional[MoESpec] = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"            # mlp activation: silu (SwiGLU) | gelu

    # Encoder-decoder (whisper): encoder layer count; 0 = decoder-only.
    encoder_layers: int = 0
    encoder_seq: int = 1500      # whisper: 30 s of audio → 1500 frames

    # Multimodal stub frontends (see DESIGN.md carve-out).
    frontend: Optional[str] = None   # None | "audio_stub" | "vision_stub"
    n_patches: int = 0               # VLM: stub patch embeddings per sample

    dtype: str = "bfloat16"
    max_pos: int = 32768   # learned-positional-table length (rope="none"
                           # attention archs only; recurrent archs skip it)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_repeats(self) -> int:
        assert self.n_layers % len(self.layer_pattern) == 0, (
            f"{self.arch_id}: n_layers={self.n_layers} not a multiple of "
            f"pattern {self.layer_pattern}"
        )
        return self.n_layers // len(self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no unbounded-window attention layer, or
        recurrent/hybrid family (bounded state or windowed KV); dense archs
        qualify only via their own local-window pattern (gemma3's global
        layers decode linearly with a seq-sharded KV — see DESIGN.md)."""
        kinds = set(self.layer_pattern)
        if kinds <= {"local", "rglru", "mlstm", "slstm"}:
            return True
        # global attention present: allowed only for the hybrid/ssm/mixed
        # local:global families (bounded fraction of global layers).
        return "attn" in kinds and len(kinds) > 1

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decoding path

    # -- parameter counting (analytic; verified against init in tests) ----
    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd, h, kv = self.hd, self.n_heads, self.n_kv_heads
        n = 0
        per_kind: dict[str, int] = {}
        for kind in set(self.layer_pattern):
            if kind in ("attn", "local"):
                p = d * h * hd + 2 * d * kv * hd + h * hd * d  # q,k,v,o
                if self.qkv_bias:
                    p += (h + 2 * kv) * hd
            elif kind == "rglru":
                # in-proj ×2 + conv4 + r/i gates + out proj (recurrent.py).
                p = 5 * d * d + 4 * d
            elif kind == "mlstm":
                # up ×2 (d→2d) + q/k/v (2d→2d) + gates + down (2d→d).
                p = 18 * d * d + 2 * d * 2 * self.n_heads
            elif kind == "slstm":
                # x-gates (d→4d) + recurrent gates (d→4d) + out proj.
                p = 9 * d * d + 4 * d
            else:
                raise ValueError(kind)
            per_kind[kind] = p
        for kind in self.layer_pattern:
            n += per_kind[kind] + 2 * d  # + norms
        n *= self.pattern_repeats
        # FFN per layer
        if self.moe is not None:
            e = self.moe
            ffn = (e.n_experts + e.n_shared_experts) * 3 * d * e.d_expert \
                + d * e.n_experts
        elif ff > 0:
            ffn = 3 * d * ff if self.act == "silu" else 2 * d * ff
        else:
            ffn = 0
        n += self.n_layers * (ffn + (2 * d if ffn else 0))
        n += v * d  # embeddings
        if not self.tie_embeddings:
            n += v * d
        has_attn = any(k in ("attn", "local") for k in self.layer_pattern)
        if self.rope == "none" and has_attn:
            # learned positional table (attention archs only; recurrent
            # stacks are order-aware — mirrors LM._needs_pos_table)
            n += self.max_pos * d
        if self.encoder_layers:
            enc = self.encoder_layers * (
                d * h * hd + 2 * d * kv * hd + h * hd * d
                + (3 * d * ff if self.act == "silu" else 2 * d * ff) + 4 * d
            )
            # cross-attention in every decoder layer
            n += enc + self.n_layers * (d * h * hd + 2 * d * kv * hd
                                        + h * hd * d + 2 * d)
        return int(n)

    def active_param_count(self) -> int:
        """MoE: params touched per token (6·N_active·D flops convention)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total_ffn = (e.n_experts + e.n_shared_experts) * 3 * self.d_model \
            * e.d_expert * self.n_layers
        active_ffn = (e.top_k + e.n_shared_experts) * 3 * self.d_model \
            * e.d_expert * self.n_layers
        return int(self.param_count() - total_ffn + active_ffn)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/pattern, tiny dims."""
        g = len(self.layer_pattern)
        d = min(self.d_model, 256)
        h = max(2, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, 2))
        moe = None
        if self.moe is not None:
            # capacity_factor ≥ E/k ⇒ capacity = n_tokens ⇒ provably no
            # drops (each token hits an expert at most once) — keeps the
            # reduced smoke tests' decode/forward consistency exact.
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_expert=128,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                capacity_factor=4.0,
            )
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-reduced",
            n_layers=g if g >= 2 else 2,
            d_model=d,
            n_heads=h,
            n_kv_heads=kv,
            head_dim=d // h,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            window=min(self.window, 64),
            moe=moe,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            n_patches=min(self.n_patches, 16),
            dtype="float32",
        )


# ---------------------------------------------------------------- shapes --
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    # Import the per-arch modules lazily so registration is on demand.
    from . import ALL_ARCHS  # noqa: F401  (triggers registration)

    try:
        return _REGISTRY[arch_id]
    except KeyError as e:
        raise ValueError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}"
        ) from e


def list_archs() -> list[str]:
    from . import ALL_ARCHS  # noqa: F401

    return sorted(_REGISTRY)
