"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts, top-8, per-expert
FFN hidden 768, GQA 32q/4kv."""
from .base import ModelConfig, MoESpec, register

QWEN3_MOE_30B_A3B = register(ModelConfig(
    arch_id="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,    # per-expert hidden
    vocab=151936,
    layer_pattern=("attn",),
    moe=MoESpec(n_experts=128, top_k=8, d_expert=768),
    rope="standard",
    rope_theta=1e6,
    act="silu",
    source="hf:Qwen/Qwen3-30B-A3B",
))
