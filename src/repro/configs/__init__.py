"""Per-architecture configs (assigned pool) + the paper's own models."""
from .base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoESpec,
    get_config,
    list_archs,
    register,
)

# Importing these modules registers every assigned architecture.
from . import (  # noqa: F401,E402
    gemma3_12b,
    kimi_k2_1t_a32b,
    qwen2_7b,
    qwen2_vl_2b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    tinyllama_1_1b,
    whisper_large_v3,
    xlstm_350m,
    yi_34b,
)

ALL_ARCHS = [
    "qwen2-7b",
    "xlstm-350m",
    "whisper-large-v3",
    "kimi-k2-1t-a32b",
    "tinyllama-1.1b",
    "recurrentgemma-9b",
    "gemma3-12b",
    "qwen2-vl-2b",
    "yi-34b",
    "qwen3-moe-30b-a3b",
]
