"""Qwen2-VL-2B [arXiv:2409.12191] — VLM backbone with M-RoPE and dynamic
resolution. The ViT vision encoder + projector is a STUB: `input_specs`
feeds precomputed patch embeddings (B, n_patches, d) prepended to the
text tokens; M-RoPE assigns (t, h, w) positions to the patch span."""
from .base import ModelConfig, register

QWEN2_VL_2B = register(ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    layer_pattern=("attn",),
    qkv_bias=True,
    rope="mrope",
    rope_theta=1e6,
    act="silu",
    frontend="vision_stub",
    n_patches=256,          # one 16×16 patch grid per sample (stub)
    tie_embeddings=True,
    source="arXiv:2409.12191",
))
