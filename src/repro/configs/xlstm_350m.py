"""xLSTM-350M [arXiv:2405.04517] — sLSTM + mLSTM blocks (assignment:
24L, d=1024, 4 heads). Pattern: one sLSTM per five mLSTM blocks (the
paper's [7:1]-style sparse sLSTM placement, adapted to 24 layers)."""
from .base import ModelConfig, register

_PATTERN = ("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm")  # ×4 = 24

XLSTM_350M = register(ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,          # xLSTM blocks carry their own up-projections
    vocab=50304,
    layer_pattern=_PATTERN,
    rope="none",
    act="gelu",
    source="arXiv:2405.04517",
))
