"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — trillion-param MoE (paper-table):
384 experts top-8, one shared expert, per-expert FFN hidden 2048,
GQA 64q/8kv. (K2's MLA attention is replaced by the assignment's GQA
spec — the assignment fixes head counts explicitly.)"""
from .base import ModelConfig, MoESpec, register

KIMI_K2_1T_A32B = register(ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,   # per-expert hidden (the assignment's d_ff for MoE archs)
    vocab=163840,
    layer_pattern=("attn",),
    moe=MoESpec(n_experts=384, top_k=8, d_expert=2048,
                n_shared_experts=1),
    rope="standard",
    rope_theta=5e4,
    act="silu",
    source="arXiv:2501.kimi2",
))
