"""Gemma3-12B [hf:google/gemma-3-1b-pt family] — 5:1 local:global sliding
window attention, 128k context. Local window 1024."""
from .base import ModelConfig, register

GEMMA3_12B = register(ModelConfig(
    arch_id="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    layer_pattern=("local",) * 5 + ("attn",),  # 5:1 local:global, ×8 = 48
    window=1024,
    rope="standard",
    rope_theta=1e6,
    act="gelu",
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
))
