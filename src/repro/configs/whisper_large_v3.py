"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder; the mel +
conv2 frontend is a STUB (`input_specs` feeds precomputed frame
embeddings (B, 1500, d) into the real encoder stack; see DESIGN.md)."""
from .base import ModelConfig, register

WHISPER_LARGE_V3 = register(ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,          # MHA
    d_ff=5120,
    vocab=51866,
    layer_pattern=("attn",),
    rope="none",            # learned positional embeddings
    act="gelu",
    frontend="audio_stub",
    tie_embeddings=True,
    source="arXiv:2212.04356",
))
