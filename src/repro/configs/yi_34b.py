"""Yi-34B [arXiv:2403.04652] — llama-arch GQA (56q/8kv)."""
from .base import ModelConfig, register

YI_34B = register(ModelConfig(
    arch_id="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    layer_pattern=("attn",),
    rope="standard",
    rope_theta=5e6,
    act="silu",
    source="arXiv:2403.04652",
))
